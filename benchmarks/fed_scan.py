"""Rounds/sec: eager vs compiled scan federated engine (DESIGN.md §9).

The eager engine dispatches ~10 separate programs per round (local fit,
masked select, uplink, CKA refresh, eqn-(3) weights, aggregation, install,
eval) plus per-round host syncs; the scan engine fuses the whole round and
scans it over chunks, paying one dispatch and one host sync per chunk.

The measured scenario is the regime the engine exists for — many cheap
rounds: a small synthetic LM-backbone classification task (1-layer d=32
transformer, rank-4 tri-LoRA, seq 8) federated over m = 10 clients with
cross-device partial participation (50% sampled, 20% stragglers), where
CE-LoRA's r×r payload makes the per-round math tiny and the eager
engine's Python/dispatch overhead dominates.  Rounds/sec comes from the
per-round ``wall_s`` the runtime records, so one-shot setup is excluded
for both engines, and both engines are warmed with a one-chunk run first.

Usage:  PYTHONPATH=src python benchmarks/fed_scan.py [--quick] [--json F]

Prints CSV (engine,rounds,rounds_per_sec,final_mean_acc) plus the
speedup; the full (non ``--quick``) run asserts speedup >= 2x.  With
``--json`` the results are also written as a machine-readable report
(uploaded as a CI artifact, see .github/workflows/ci.yml).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax  # noqa: E402

from repro.core.fed_model import FedTask  # noqa: E402
from repro.core.federated import FedConfig, run_federated  # noqa: E402
from repro.data import synthetic  # noqa: E402
from repro.models.config import ModelConfig  # noqa: E402

SEQ, VOCAB, N_CLASSES = 8, 256, 6


def bench_setup(m: int):
    cfg = ModelConfig(
        name="scanbench", family="dense", n_layers=1, d_model=32, n_heads=2,
        n_kv_heads=2, head_dim=16, d_ff=64, vocab_size=VOCAB, rope_theta=1e4,
        layer_pattern=("attn",), param_dtype="float32", lora_rank=4)
    task = FedTask.create(jax.random.key(0), cfg, N_CLASSES)
    ctrain, ctest, _ = synthetic.make_federated_classification(
        0, m, 40, 24, SEQ, VOCAB, N_CLASSES, alpha=0.5, drift=1.5,
        n_groups=3, class_sep=1.2)
    return task, ctrain, ctest


def run_engine(engine: str, task, ctrain, ctest, *, m: int, rounds: int,
               chunk: int) -> dict:
    fed = FedConfig(method="celora", n_clients=m, rounds=rounds,
                    local_steps=1, batch_size=2, lr=1e-2, seed=0,
                    participation=0.5, straggler_frac=0.2,
                    use_data_sim=False, cka_probes=8,   # S^model only
                    engine=engine, chunk_rounds=chunk)
    out = run_federated(task, fed, ctrain, ctest)
    wall = sum(r.wall_s for r in out["history"])
    return {"engine": engine, "rounds": rounds,
            "rounds_per_sec": rounds / wall, "wall_s": wall,
            "mean_acc": out["mean_acc"]}


def main(quick: bool = False, json_path: str | None = None) -> dict:
    m = 6 if quick else 10
    rounds = 10 if quick else 50
    chunk = 5 if quick else 10             # divides rounds: no ragged chunk
    task, ctrain, ctest = bench_setup(m)

    print(f"# fed_scan — eager vs scan engine, m={m}, rounds={rounds}, "
          f"chunk={chunk}, participation=0.5, straggler_frac=0.2")
    results = {}
    for engine in ("eager", "scan"):
        # warm the compilation caches (one chunk's worth of rounds)
        run_engine(engine, task, ctrain, ctest, m=m, rounds=chunk,
                   chunk=chunk)
        results[engine] = run_engine(engine, task, ctrain, ctest, m=m,
                                     rounds=rounds, chunk=chunk)

    print("engine,rounds,rounds_per_sec,final_mean_acc")
    for r in results.values():
        print(f"{r['engine']},{r['rounds']},{r['rounds_per_sec']:.2f},"
              f"{r['mean_acc']:.3f}")
    speedup = (results["scan"]["rounds_per_sec"]
               / results["eager"]["rounds_per_sec"])
    print(f"# scan/eager speedup: {speedup:.2f}x")
    report = {"m": m, "rounds": rounds, "chunk_rounds": chunk,
              "speedup": speedup, **{k: v for k, v in results.items()}}
    if json_path:
        Path(json_path).write_text(json.dumps(report, indent=2))
        print(f"# wrote {json_path}")
    if not quick:
        assert speedup >= 2.0, (
            f"scan engine speedup {speedup:.2f}x < 2x — the compiled "
            f"multi-round engine regressed")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default=None, metavar="F",
                    help="write a machine-readable report to F")
    a = ap.parse_args()
    main(quick=a.quick, json_path=a.json)
