"""Roofline analysis from the dry-run artifacts (deliverable g).

Per (arch × shape × mesh):
    compute term    = FLOPs / (chips · 197e12 bf16 FLOP/s)
    memory term     = HBM bytes / (chips · 819e9 B/s)
    collective term = collective bytes per chip / 50e9 B/s per ICI link

FLOPs / HBM bytes come from the ANALYTIC model (benchmarks/analytic.py) —
XLA cost_analysis counts while-loop bodies once, so scan-over-layers HLO
numbers undercount by ~n_layers; they are reported as cross-checks.

Collective bytes are parsed from the compiled (post-SPMD) HLO: every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
operand is summed, with ops inside while-loop bodies multiplied by the
loop trip count (parsed from the loop-condition constant).
"""
from __future__ import annotations

import gzip
import json
import math
import re
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent))

from analytic import count_params, step_bytes, step_flops  # noqa: E402
from repro.launch.steps import SHAPES, shape_variant  # noqa: E402
from repro.models.config import get_config  # noqa: E402

PEAK_FLOPS = 197e12        # bf16 per chip (TPU v5e)
HBM_BW = 819e9             # B/s per chip
ICI_BW = 50e9              # B/s per link
ART = Path(__file__).resolve().parent.parent / "artifacts" / "dryrun"

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
                "s8": 1, "u8": 1, "pred": 1, "s64": 8, "f64": 8, "c64": 8}
_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")


def _shape_bytes(sig: str) -> int:
    """'bf16[16,128,8]{...}' → bytes."""
    m = re.match(r"(\w+)\[([0-9,]*)\]", sig)
    if not m:
        return 0
    dt, dims = m.groups()
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dt, 4)


def parse_collectives(hlo_text: str) -> dict:
    """Collective bytes per device, trip-count corrected.

    The compiled module is the per-device program; operand sizes of
    collective ops are per-device shard sizes.  Returns totals by op type
    plus the grand total.
    """
    # 1) split into computations; note while-loop bodies and trip counts
    comps: dict[str, str] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = re.match(r"\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*\)\s*->", line)
        if m and ("{" in line or line.rstrip().endswith("{")):
            cur = m.group(1)
            comps[cur] = ""
        elif cur is not None:
            comps[cur] = comps[cur] + line + "\n"

    # 2) find while ops: body=..., condition=..., and trip count from the
    #    condition computation's compare-against constant
    trip: dict[str, int] = {}
    for cname, body in comps.items():
        for m in re.finditer(r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)", body):
            cond, wbody = m.groups()
            cnd_txt = comps.get(cond, "")
            cm = re.search(r"constant\((\d+)\)", cnd_txt)
            count = int(cm.group(1)) if cm else 1
            trip[wbody] = max(trip.get(wbody, 1), count)

    # propagate: a computation called from a while body inherits its trips
    def comp_trip(name, seen=()):
        return trip.get(name, 1)

    out = {c: 0.0 for c in _COLL}
    per_comp_coll: dict[str, dict] = {}
    for cname, body in comps.items():
        local = {c: 0.0 for c in _COLL}
        for line in body.splitlines():
            for coll in _COLL:
                if re.search(rf"=\s*(?:\([^)]*\)|\S*)\s*{coll}"
                             rf"(?:-start|-done)?\(", line) \
                   or f" {coll}(" in line:
                    # tuple-typed collectives: sum every element left of the op
                    lhs = line.split(coll)[0]
                    shapes = re.findall(r"(\w+\[[0-9,]*\])", lhs)
                    if not shapes:
                        shapes = re.findall(r"(\w+\[[0-9,]*\])", line)[:1]
                    for sh in shapes:
                        local[coll] += _shape_bytes(sh)
                    break
        per_comp_coll[cname] = local

    # 3) nested while: multiply by product of enclosing trip counts — we
    #    approximate one level (body name → trip), plus direct calls from
    #    bodies with known multipliers via fusion/call lines
    for cname, local in per_comp_coll.items():
        mult = comp_trip(cname)
        for coll, b in local.items():
            out[coll] += b * mult

    out["total"] = sum(out[c] for c in _COLL)
    out["while_trips"] = {k: v for k, v in trip.items() if v > 1}
    return out


def roofline_row(arch: str, shape_name: str, mesh_tag: str = "16x16") -> dict:
    cfg = shape_variant(get_config(arch), shape_name)
    chips = 512 if mesh_tag.startswith("2x") else 256
    fl = step_flops(cfg, shape_name)
    by = step_bytes(cfg, shape_name)
    pc = count_params(cfg)

    t_compute = fl["total"] / (chips * PEAK_FLOPS)
    t_memory = by["total"] / (chips * HBM_BW)

    rec_path = ART / mesh_tag / f"{arch}__{shape_name}.json"
    hlo_path = ART / mesh_tag / f"{arch}__{shape_name}.hlo.gz"
    coll_bytes = float("nan")
    hlo_flops = hlo_mem = float("nan")
    compiled = {}
    if rec_path.exists():
        compiled = json.loads(rec_path.read_text())
        hlo_flops = compiled.get("cost", {}).get("flops", float("nan"))
        hlo_mem = compiled.get("memory", {}).get("temp_size_in_bytes",
                                                 float("nan"))
    colls = {}
    if hlo_path.exists():
        with gzip.open(hlo_path, "rt") as f:
            colls = parse_collectives(f.read())
        coll_bytes = colls.get("total", float("nan"))
    t_coll = coll_bytes / ICI_BW if coll_bytes == coll_bytes else float("nan")

    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    valid = {k: v for k, v in terms.items() if v == v}
    dominant = max(valid, key=valid.get) if valid else "?"
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_tag,
        "variant": cfg.name,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_6nd": fl["model_flops_6nd"],
        "analytic_flops": fl["total"],
        "useful_ratio": fl["model_flops_6nd"] / fl["total"],
        "hlo_flops_raw": hlo_flops,
        "hlo_temp_bytes": hlo_mem,
        "collective_bytes_per_chip": coll_bytes,
        "collectives": {k: v for k, v in colls.items()
                        if k in _COLL and v},
        "params_total": pc.total,
    }


def table(mesh_tag: str = "16x16", archs=None, shapes=None) -> list[dict]:
    from repro.configs import ASSIGNED
    rows = []
    for a in archs or ASSIGNED:
        for s in shapes or SHAPES:
            rec = ART / mesh_tag / f"{a}__{s}.json"
            if not rec.exists():
                continue
            rows.append(roofline_row(a, s, mesh_tag))
    return rows


def fmt_markdown(rows: list[dict]) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful FLOPs ratio | coll bytes/chip |\n"
           "|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.2e} "
            f"| {r['t_memory_s']:.2e} | {r['t_collective_s']:.2e} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['collective_bytes_per_chip']:.2e} |\n")
    return "".join(out)


if __name__ == "__main__":
    mesh = sys.argv[1] if len(sys.argv) > 1 else "16x16"
    rows = table(mesh)
    print(fmt_markdown(rows))
    out = ART / f"roofline_{mesh}.json"
    out.write_text(json.dumps(rows, indent=1, default=str))
    print(f"# {len(rows)} rows -> {out}")
