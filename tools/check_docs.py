#!/usr/bin/env python
"""Docs link check (CI): every repo path mentioned in README.md / DESIGN.md
must exist, every DESIGN.md section cited from source docstrings
(``DESIGN.md §N``) must be present in DESIGN.md, and the generated API
reference (docs/API.md, tools/gen_api_docs.py) must not be stale.

Exit code 0 = all references resolve and docs/API.md is current.  The API
staleness check needs the package importable (jax installed); when it is
not, that check is skipped with a warning so the pure link lint still runs
anywhere.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOCS = ["README.md", "DESIGN.md"]
# repo-relative paths as they appear in docs (code spans, commands, prose)
PATH_RE = re.compile(
    r"\b((?:src|examples|benchmarks|tests|tools|docs|\.github)/"
    r"[\w./\-]+\.(?:py|md|toml|yml|yaml))\b")
SECTION_CITE_RE = re.compile(r"DESIGN\.md §(\d+)")
SECTION_DEF_RE = re.compile(r"^##\s*§?(\d+)", re.MULTILINE)


def main() -> int:
    bad: list[str] = []
    design = (ROOT / "DESIGN.md")
    defined = set(SECTION_DEF_RE.findall(design.read_text())) \
        if design.exists() else set()

    for doc in DOCS:
        p = ROOT / doc
        if not p.exists():
            bad.append(f"{doc}: missing")
            continue
        text = p.read_text()
        for ref in sorted(set(PATH_RE.findall(text))):
            if not (ROOT / ref).exists():
                bad.append(f"{doc}: references nonexistent path {ref}")

    # docstring citations like "DESIGN.md §3" must resolve to a section
    for src in sorted((ROOT / "src").rglob("*.py")):
        for num in set(SECTION_CITE_RE.findall(src.read_text())):
            if num not in defined:
                bad.append(f"{src.relative_to(ROOT)}: cites DESIGN.md §{num} "
                           f"but DESIGN.md has no section §{num}")

    # generated API reference must match a fresh regeneration
    sys.path.insert(0, str(ROOT / "src"))
    sys.path.insert(0, str(ROOT / "tools"))
    try:
        import gen_api_docs
        api = ROOT / "docs" / "API.md"
        if not api.exists():
            bad.append("docs/API.md: missing — run python tools/gen_api_docs.py")
        elif api.read_text() != gen_api_docs.generate():
            bad.append("docs/API.md: stale — run python tools/gen_api_docs.py "
                       "and commit the result")
    except ImportError as e:                      # no jax in this env
        print(f"warning: skipping docs/API.md staleness check ({e})")

    if bad:
        print("docs check FAILED:")
        for b in bad:
            print(f"  - {b}")
        return 1
    print(f"docs check OK ({', '.join(DOCS)}; "
          f"{len(defined)} DESIGN.md sections; docs/API.md)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
